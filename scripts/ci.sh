#!/usr/bin/env bash
# Tier-1 test entry point (ROADMAP.md): run from the repo root.
#
#   scripts/ci.sh             full tier-1 suite
#   scripts/ci.sh fast        quick subset (-m fast) for per-push feedback
#   scripts/ci.sh bench       agg micro-bench smoke + comm-efficiency grid
#                             + buffered-async throughput grid + the
#                             training-throughput smoke: writes
#                             BENCH_agg.json, BENCH_comm.json,
#                             BENCH_async.json and BENCH_train.smoke.json
#                             and FAILS if the pruned selection network
#                             is slower than 0.7x the XLA-sort median
#                             baseline at m=32, if any comm cell violates
#                             its (codec-scaled) core/theory.py bound, if
#                             tau>=4 local-update rounds save less than
#                             4x bytes vs tau=1 under ALIE, if int8
#                             compression saves less than 3x bytes vs
#                             uncompressed at matched error under ALIE,
#                             if any async cell
#                             breaks its effective-m bound, if the
#                             k/m=0.5 buffer closes rounds < 2x faster
#                             than sync under heavy-tailed latency at
#                             matched clean error, if any trainer-window
#                             HLO structure check fails (collective
#                             counts, xdevice_steps byte scaling, no host
#                             transfer in the scan window), or if the
#                             COMMITTED BENCH_train.json stops showing
#                             <10% robust-aggregation step-time overhead
#                             vs plain data-parallel at the largest
#                             config (run.py --gate-train; the committed
#                             full grid is regenerated offline with
#                             python -m benchmarks.train_throughput
#                             --json BENCH_train.json — don't clobber it
#                             with the smoke artifact), or if the
#                             COMMITTED BENCH_serve.json stops showing
#                             <15% robust-cadence tokens/s overhead vs
#                             serve-only at the largest slot count
#                             (run.py --gate-serve; regenerated offline
#                             with python -m benchmarks.serve_throughput
#                             --json BENCH_serve.json), or if any serve
#                             cell recompiled mid-stream
#   scripts/ci.sh serve       serving smoke: continuous-batching engine +
#                             robust continual adaptation end-to-end
#                             twice on the debug mesh, FAILS unless both
#                             runs print the same "final iterate sha256"
#                             line (seeded traffic, poisoned feedback,
#                             robust rounds, hot-swaps — all
#                             bit-deterministic)
#   scripts/ci.sh docs        registry-generated README tables
#                             (python -m repro.docs --check): FAILS if the
#                             attack/aggregator/strategy/compression/policy
#                             tables drifted from the registries
#                             (regenerate: python -m repro.docs)
#   scripts/ci.sh robustness  attack x aggregator x alpha scenario matrix
#                             plus the compressed-payload codec cells,
#                             the buffered-async stale-exploit cells and
#                             the poisoned-feedback serving cells
#                             (repro.attacks.matrix --smoke): writes
#                             ROBUSTNESS.smoke.json (the committed
#                             ROBUSTNESS.json is the full grid — don't
#                             clobber it) and FAILS if any gated cell's
#                             final error violates its core/theory.py
#                             bound (sync rate, or the effective-m async
#                             rate for buffered cells)
#   scripts/ci.sh resume      kill-and-resume smoke on the fed CLI: run 6
#                             rounds uninterrupted, then 4 rounds with
#                             --ckpt-dir (the "kill") and --resume to 6,
#                             and FAIL unless both print the same
#                             "final iterate sha256" line (the
#                             rounds.engine bit-for-bit resume contract,
#                             DESIGN.md §Round engine)
#   scripts/ci.sh lint        ruff check (F + E9 repo-wide, pyproject.toml)
#                             + ruff format check on scripts/ — requires
#                             ruff on PATH; the GitHub lint job installs it
#
# Env-dependent tests (newer-jax shard_map/set_mesh API, cost_analysis
# dict-vs-list) are skipif/xfail-guarded in the test files, so the
# pass/fail counts are clean on every jax the CI matrix installs; the
# GitHub workflow enforces the pass floor and failure ceiling.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [ "${1:-}" = "fast" ]; then
    exec python -m pytest -q -m fast
fi
if [ "${1:-}" = "bench" ]; then
    # agg timings are --smoke (wall-clock budget); the comm and async
    # grids are deterministic statistics, so they run their committed
    # full configs for clean per-cell diffs against the BENCH_comm.json
    # and BENCH_async.json baselines
    python -m benchmarks.run --only agg --json BENCH_agg.json --smoke --gate-agg || exit 1
    python -m benchmarks.run --only comm --json-comm BENCH_comm.json || exit 1
    python -m benchmarks.run --only async --json-async BENCH_async.json || exit 1
    # train: the smoke grid re-verifies the HLO structure gates on this
    # host; the <10% overhead gate is a deterministic re-check of the
    # COMMITTED full-grid numbers (immune to runner wall-clock noise)
    python -m benchmarks.run --only train --smoke \
        --json-train BENCH_train.smoke.json --gate-train BENCH_train.json || exit 1
    # serve: same split — the smoke grid re-verifies the no-recompile
    # contract live; the <15% robust-cadence overhead gate re-checks the
    # COMMITTED BENCH_serve.json (regenerated offline with
    # python -m benchmarks.serve_throughput --json BENCH_serve.json)
    exec python -m benchmarks.run --only serve --smoke \
        --json-serve BENCH_serve.smoke.json --gate-serve BENCH_serve.json
fi
if [ "${1:-}" = "docs" ]; then
    exec python -m repro.docs --check
fi
if [ "${1:-}" = "robustness" ]; then
    exec python -m repro.attacks.matrix --smoke --json ROBUSTNESS.smoke.json
fi
if [ "${1:-}" = "resume" ]; then
    tmp="$(mktemp -d)"
    trap 'rm -rf "$tmp"' EXIT
    common="--clients 64 --cohort 32 --chunk 8 --dim 12 --rounds 6 --lr 0.3
            --alpha 0.25 --attack alie,sign_flip --schedule greedy
            --method median"
    full=$(python -m repro.fed.run $common | grep 'final iterate sha256') || exit 1
    python -m repro.fed.run $common --rounds 4 --ckpt-dir "$tmp/ck" \
        >/dev/null || exit 1
    res=$(python -m repro.fed.run $common --ckpt-dir "$tmp/ck" --resume \
        | grep 'final iterate sha256') || exit 1
    echo "uninterrupted: $full"
    echo "resumed:       $res"
    if [ "$full" != "$res" ]; then
        echo "resume smoke FAILED: final iterate digests differ" >&2
        exit 1
    fi
    echo "resume smoke OK (bit-for-bit)"
    exit 0
fi
if [ "${1:-}" = "serve" ]; then
    # serving smoke: run the continuous-batching engine + robust
    # continual adaptation end-to-end TWICE on the debug mesh and FAIL
    # unless both print the same "final iterate sha256" line — the
    # traffic, feedback corruption, robust rounds, and hot-swaps are all
    # seeded, so the served iterate is bit-deterministic
    common="--smoke --arch llama3_2_3b --requests 24 --slots 3 --shards 2
            --num-users 1000 --alpha 0.5 --attack feedback_flip
            --adapt-every 8 --batch-per-shard 2 --method median"
    one=$(python -m repro.serve.run $common | grep 'final iterate sha256') || exit 1
    two=$(python -m repro.serve.run $common | grep 'final iterate sha256') || exit 1
    echo "run 1: $one"
    echo "run 2: $two"
    if [ "$one" != "$two" ]; then
        echo "serve smoke FAILED: final iterate digests differ" >&2
        exit 1
    fi
    echo "serve smoke OK (bit-deterministic)"
    exit 0
fi
if [ "${1:-}" = "lint" ]; then
    if ! command -v ruff >/dev/null 2>&1; then
        echo "scripts/ci.sh lint: ruff not installed (pip install ruff)" >&2
        exit 1
    fi
    ruff check . || exit 1
    exec ruff format --check scripts
fi
exec python -m pytest -q
