#!/usr/bin/env bash
# Tier-1 test entry point (ROADMAP.md): run from the repo root.
#
#   scripts/ci.sh        full tier-1 suite
#   scripts/ci.sh fast   quick subset (-m fast) for per-push feedback
#
# Tracks the seed baseline instead of leaving it silent: some tests are
# env-dependent (newer-jax shard_map API, TPU-only lowerings) — the
# GitHub workflow records the pass/fail counts on every run so drift is
# visible in CI history.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [ "${1:-}" = "fast" ]; then
    exec python -m pytest -q -m fast
fi
exec python -m pytest -q
