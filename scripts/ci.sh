#!/usr/bin/env bash
# Tier-1 test entry point (ROADMAP.md): run from the repo root.
#
#   scripts/ci.sh        full tier-1 suite
#   scripts/ci.sh fast   quick subset (-m fast) for per-push feedback
#   scripts/ci.sh bench  agg micro-bench smoke: writes BENCH_agg.json and
#                        FAILS if the pruned selection network is slower
#                        than the XLA-sort median baseline at m=32
#
# Tracks the seed baseline instead of leaving it silent: some tests are
# env-dependent (newer-jax shard_map API, TPU-only lowerings) — the
# GitHub workflow records the pass/fail counts on every run so drift is
# visible in CI history.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [ "${1:-}" = "fast" ]; then
    exec python -m pytest -q -m fast
fi
if [ "${1:-}" = "bench" ]; then
    exec python -m benchmarks.run --only agg --json BENCH_agg.json --smoke --gate-agg
fi
exec python -m pytest -q
