"""Diff two benchmark JSON artifacts and print per-case deltas.

Used by the CI bench job to compare fresh runs against the committed
baselines in the job summary (markdown tables).  Informational only —
the hard gates stay in benchmarks/run.py (``--gate-agg``),
benchmarks/comm_efficiency.py (theory bounds + byte-saving floor), and
benchmarks/async_throughput.py (effective-m bounds + speedup floor);
this diff makes drift visible per case so a slow regression inside the
gate margins still shows up in CI history.

Handles both artifact schemas, keyed off the payload's ``suite`` field:

- ``agg``  (BENCH_agg.json)  — (op, m, d) cases: µs/call + speedup
  vs the XLA-sort baseline (timing, noisy on shared runners);
- ``comm`` (BENCH_comm.json) — (tau, strategy, compression, attack)
  cells: final error, theory bound, rounds/bytes to the fixed target
  error (deterministic statistics — any delta is a real behaviour
  change; pre-compression baselines key as compression='none');
- ``async`` (BENCH_async.json) — (attack, k/m, dropout) cells: final
  error + simulated round time and the speedup vs the k = m sync
  column (also deterministic — the clock is the seeded arrival model);
- ``train`` (BENCH_train.json) — (config, strategy, attack) cells: step
  time and tokens/sec of the device-steps trainer (wall-clock timing,
  noisy on shared runners — the hard <10%-overhead gate re-checks the
  committed numbers deterministically via ``run.py --gate-train``);
- ``serve`` (BENCH_serve.json) — (slots, adapt_every) cells: tokens/sec
  and tick latency of the continuous-batching serve engine with robust
  continual adaptation on cadence (also wall-clock — the <15%-overhead
  gate re-checks the committed numbers via ``run.py --gate-serve``).

A MISSING ``--base`` file is not an error: when a brand-new suite lands,
its first committed baseline doesn't exist yet on the base branch — the
diff reports "new suite" and exits 0 so CI stays green on the landing PR.

    python scripts/bench_diff.py --base OLD.json --new NEW.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _fmt(v, spec=".2f", suffix=""):
    if isinstance(v, (int, float)):
        return f"{v:{spec}}{suffix}"
    return "—"


def _diff_agg(base: dict, new: dict) -> None:
    def index(payload):
        return {(r["op"], r["m"], r["d"]): r for r in payload.get("records", [])}

    base, new = index(base), index(new)
    print("### Agg micro-bench vs committed baseline")
    print()
    print("| op | m | d | base µs | new µs | µs Δ | base speedup | new speedup |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(new):
        op, m, d = key
        nr = new[key]
        br = base.get(key)
        if br is None:
            print(f"| {op} | {m} | {d} | — | {nr['us']:.1f} | new case | — | "
                  f"{nr['speedup'] if nr['speedup'] is not None else '—'} |")
            continue
        dus = nr["us"] - br["us"]
        print(f"| {op} | {m} | {d} | {br['us']:.1f} | {nr['us']:.1f} | "
              f"{dus:+.1f} | {_fmt(br.get('speedup'), '.2f', 'x')} | "
              f"{_fmt(nr.get('speedup'), '.2f', 'x')} |")
    _dropped(base, new)


def _diff_comm(base: dict, new: dict) -> None:
    def index(payload):
        # compression landed after the first committed baselines — key
        # pre-compression records as their 'none' cells so the diff
        # lines up instead of reporting a full grid swap
        return {(str(r["tau"]), r["strategy"],
                 r.get("compression", "none"), r["attack"]): r
                for r in payload.get("records", [])}

    base, new = index(base), index(new)
    print("### Comm-efficiency grid vs committed baseline")
    print()
    print("| tau | strategy | compression | attack | base err | new err | "
          "err Δ | base bytes→target | new bytes→target |")
    print("|---|---|---|---|---|---|---|---|---|")
    def tau_order(k):
        tau = k[0]
        return (k[1], k[2], k[3], float("inf") if tau == "inf" else int(tau))

    for key in sorted(new, key=tau_order):
        tau, strategy, comp, attack = key
        nr = new[key]
        br = base.get(key)
        if br is None:
            print(f"| {tau} | {strategy} | {comp} | {attack} | — | "
                  f"{nr['err']:.4f} | "
                  f"new case | — | {_fmt(nr.get('bytes_to_target'), ',.0f')} |")
            continue
        derr = nr["err"] - br["err"]
        print(f"| {tau} | {strategy} | {comp} | {attack} | {br['err']:.4f} | "
              f"{nr['err']:.4f} | {derr:+.4f} | "
              f"{_fmt(br.get('bytes_to_target'), ',.0f')} | "
              f"{_fmt(nr.get('bytes_to_target'), ',.0f')} |")
    _dropped(base, new)


def _diff_async(base: dict, new: dict) -> None:
    def index(payload):
        return {(r["attack"], r["k_frac"], r["dropout"]): r
                for r in payload.get("records", [])}

    base, new = index(base), index(new)
    print("### Buffered-async throughput grid vs committed baseline")
    print()
    print("| attack | k/m | dropout | base err | new err | err Δ | "
          "base speedup | new speedup |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(new):
        attack, k_frac, dropout = key
        nr = new[key]
        br = base.get(key)
        if br is None:
            print(f"| {attack} | {k_frac} | {dropout} | — | "
                  f"{nr['err']:.4f} | new case | — | "
                  f"{_fmt(nr.get('speedup_vs_sync'), '.2f', 'x')} |")
            continue
        derr = nr["err"] - br["err"]
        print(f"| {attack} | {k_frac} | {dropout} | {br['err']:.4f} | "
              f"{nr['err']:.4f} | {derr:+.4f} | "
              f"{_fmt(br.get('speedup_vs_sync'), '.2f', 'x')} | "
              f"{_fmt(nr.get('speedup_vs_sync'), '.2f', 'x')} |")
    _dropped(base, new)


def _diff_train(base: dict, new: dict) -> None:
    def index(payload):
        return {(r["config"], r["strategy"], r["attack"]): r
                for r in payload.get("records", [])
                if r.get("status") == "ok"}

    base, new = index(base), index(new)
    print("### Training-throughput grid vs committed baseline")
    print()
    print("| config | strategy | attack | base ms/step | new ms/step | "
          "ms Δ | base tok/s | new tok/s |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(new):
        config, strategy, attack = key
        nr = new[key]
        br = base.get(key)
        if br is None:
            print(f"| {config} | {strategy} | {attack} | — | "
                  f"{_fmt(nr.get('step_time_ms'), '.1f')} | new case | — | "
                  f"{_fmt(nr.get('tokens_per_s'), ',.0f')} |")
            continue
        dms = nr["step_time_ms"] - br["step_time_ms"]
        print(f"| {config} | {strategy} | {attack} | "
              f"{br['step_time_ms']:.1f} | {nr['step_time_ms']:.1f} | "
              f"{dms:+.1f} | {_fmt(br.get('tokens_per_s'), ',.0f')} | "
              f"{_fmt(nr.get('tokens_per_s'), ',.0f')} |")
    _dropped(base, new)


def _diff_serve(base: dict, new: dict) -> None:
    def index(payload):
        return {(r["slots"], r["adapt_every"]): r
                for r in payload.get("records", [])
                if r.get("status") == "ok"}

    base, new = index(base), index(new)
    print("### Serve-throughput grid vs committed baseline")
    print()
    print("| slots | adapt_every | base tok/s | new tok/s | tok/s Δ | "
          "base p99 | new p99 | rounds |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(new):
        slots, cadence = key
        nr = new[key]
        br = base.get(key)
        if br is None:
            print(f"| {slots} | {cadence} | — | "
                  f"{_fmt(nr.get('tok_per_s'), ',.0f')} | new case | — | "
                  f"{_fmt(nr.get('p99_latency_ticks'), '.1f')} | "
                  f"{nr.get('rounds', 0)} |")
            continue
        dtps = nr["tok_per_s"] - br["tok_per_s"]
        print(f"| {slots} | {cadence} | {br['tok_per_s']:,.0f} | "
              f"{nr['tok_per_s']:,.0f} | {dtps:+,.0f} | "
              f"{_fmt(br.get('p99_latency_ticks'), '.1f')} | "
              f"{_fmt(nr.get('p99_latency_ticks'), '.1f')} | "
              f"{nr.get('rounds', 0)} |")
    _dropped(base, new)


def _dropped(base: dict, new: dict) -> None:
    dropped = sorted(set(base) - set(new))
    if dropped:
        print()
        print(f"dropped cases (in baseline, not in fresh run): {dropped}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", required=True, help="committed baseline json")
    ap.add_argument("--new", required=True, help="fresh run json")
    args = ap.parse_args(argv)
    with open(args.new) as f:
        new = json.load(f)
    suite = new.get("suite", "agg")
    try:
        with open(args.base) as f:
            base = json.load(f)
    except FileNotFoundError:
        # brand-new suite: no committed baseline exists yet on the base
        # branch — nothing to diff, and that must not fail the job
        print(f"### {suite} suite: new suite — no committed baseline at "
              f"{args.base} yet ({len(new.get('records', []))} fresh "
              f"records, nothing to diff)")
        return 0
    if base.get("suite", "agg") != suite:
        print(f"suite mismatch: baseline {base.get('suite')!r} vs "
              f"fresh {suite!r}", file=sys.stderr)
        return 2
    if suite == "comm":
        _diff_comm(base, new)
    elif suite == "async":
        _diff_async(base, new)
    elif suite == "train":
        _diff_train(base, new)
    elif suite == "serve":
        _diff_serve(base, new)
    else:
        _diff_agg(base, new)
    return 0


if __name__ == "__main__":
    sys.exit(main())
