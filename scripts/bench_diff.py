"""Diff two BENCH_agg.json files and print per-case speedup deltas.

Used by the CI bench job to compare the fresh run against the committed
baseline in the job summary (markdown table).  Informational only — the
hard gate stays benchmarks/run.py --gate-agg (0.7x floor vs the XLA-sort
baseline); this diff makes drift visible per (op, m, d) case so a slow
regression inside the gate margin still shows up in CI history.

    python scripts/bench_diff.py --base OLD.json --new NEW.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _index(payload: dict) -> dict:
    return {(r["op"], r["m"], r["d"]): r for r in payload.get("records", [])}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", required=True, help="committed baseline json")
    ap.add_argument("--new", required=True, help="fresh run json")
    args = ap.parse_args(argv)
    with open(args.base) as f:
        base = _index(json.load(f))
    with open(args.new) as f:
        new = _index(json.load(f))

    print("### Agg micro-bench vs committed baseline")
    print()
    print("| op | m | d | base µs | new µs | µs Δ | base speedup | new speedup |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(new):
        op, m, d = key
        nr = new[key]
        br = base.get(key)
        if br is None:
            print(f"| {op} | {m} | {d} | — | {nr['us']:.1f} | new case | — | "
                  f"{nr['speedup'] if nr['speedup'] is not None else '—'} |")
            continue
        dus = nr["us"] - br["us"]
        bs = br.get("speedup")
        ns = nr.get("speedup")
        fmt = lambda v: f"{v:.2f}x" if isinstance(v, (int, float)) else "—"
        print(f"| {op} | {m} | {d} | {br['us']:.1f} | {nr['us']:.1f} | "
              f"{dus:+.1f} | {fmt(bs)} | {fmt(ns)} |")
    dropped = sorted(set(base) - set(new))
    if dropped:
        print()
        print(f"dropped cases (in baseline, not in fresh run): {dropped}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
